"""Trace-driven workload family + session affinity + config API (§13).

Covers the DESIGN.md §13 layer end to end:

  * every ``WORKLOADS`` member is seed-deterministic, and the degenerate
    configs reproduce the historical PR 1–9 Poisson trace bit for bit;
  * multi-turn sessions carry cumulative context (prompt = prefix + new),
    tenant classes map onto priorities and SLO sampling;
  * ``PrefixStore`` LRU residency + checkpoint-backed KV pages;
  * warm-hit prefill skipping, priority draining, preemption, shedding;
  * the frozen ``ServeConfig`` / ``FleetConfig`` API and its deprecated
    kwarg/alias shims (byte-identical, warning on the legacy path).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.serve import (ContinuousBatcher, FleetConfig, OffloadAwareScheduler,
                         OnlineCalibrator, PrefixStore, Request, ServeConfig,
                         SimulatedFabric, TENANT_CLASSES, WORKLOADS,
                         WorkloadSpec, serve_fleet, serve_workload,
                         synthetic_workload, workload_for)

#: Single-turn smoke trace (the PR 9 shape) for the inertness identities.
SINGLE_TURN = WorkloadSpec(num_requests=64, rate_rps=2e6, seed=7)
#: Bursty multi-tenant session trace for the affinity paths.
SESSIONS = WorkloadSpec(num_requests=48, rate_rps=1e6, arrival="mmpp",
                        turns=4, think_time_s=(1e-6, 5e-6), tenants=3,
                        tenant_classes=("premium", "standard", "batch"),
                        seed=7)


def _trace_key(reqs):
    return [(r.rid, r.arrival, r.prompt_len, r.gen_len, r.slo_cycles,
             r.session, r.turn, r.tenant, r.priority, r.prefix_id,
             r.prefix_len) for r in reqs]


def _served_key(out):
    return [(r.rid, r.t_done, r.slo_met, r.state.value)
            for r in out["requests"]]


# --------------------------------------------------------------------------- #
# Workload family
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arrival", sorted(WORKLOADS))
def test_every_family_member_is_seed_deterministic(arrival):
    spec = WorkloadSpec(num_requests=48, arrival=arrival, turns=3,
                        tenants=2, think_time_s=(1e-6, 2e-6), seed=5)
    a, b = spec.build(), spec.build()
    assert _trace_key(a) == _trace_key(b)
    assert all(np.array_equal(x.tokens, y.tokens) for x, y in zip(a, b))
    other = dataclasses.replace(spec, seed=6).build()
    assert _trace_key(a) != _trace_key(other)


def test_degenerate_session_spec_is_bitidentical_to_poisson():
    """turns=1 + zero think-time + one tenant == the historical stream:
    the session machinery must consume no extra rng state."""
    base = SINGLE_TURN.build()
    degenerate = dataclasses.replace(SINGLE_TURN, turns=1,
                                     think_time_s=(0.0, 0.0),
                                     tenants=1).build()
    assert _trace_key(base) == _trace_key(degenerate)
    assert all(np.array_equal(x.tokens, y.tokens)
               for x, y in zip(base, degenerate))
    # Single-turn requests carry exactly the PR 1-9 shape: no session
    # metadata, default priority, zero prefix.
    assert all(r.session is None and r.prefix_id is None
               and r.prefix_len == 0 and r.priority == 1 for r in base)


def test_gamma_and_mmpp_are_burstier_than_poisson():
    n = 4096
    cvs = {}
    for arrival in WORKLOADS:
        spec = WorkloadSpec(num_requests=n, arrival=arrival, seed=3)
        gaps = np.diff([r.arrival for r in spec.build(with_tokens=False)])
        cvs[arrival] = gaps.std() / gaps.mean()
    assert cvs["poisson"] == pytest.approx(1.0, abs=0.1)
    assert cvs["gamma"] > 1.5          # cv=3 renewal process
    assert cvs["mmpp"] > 1.1           # ON/OFF bursts (default 20% duty)
    # Same mean rate across families (the traces are burstier, not heavier).
    for arrival in ("gamma", "mmpp"):
        spec = WorkloadSpec(num_requests=n, arrival=arrival, seed=3)
        reqs = spec.build(with_tokens=False)
        mean_rate = (len(reqs) - 1) / (reqs[-1].arrival / 1e9)
        assert mean_rate == pytest.approx(spec.rate_rps, rel=0.2)


def test_heavy_tail_lengths_are_clipped_to_the_mix():
    for dist in ("lognormal", "zipf"):
        spec = WorkloadSpec(num_requests=256, length_dist=dist, seed=2)
        reqs = spec.build(with_tokens=False)
        lens = {r.prompt_len for r in reqs}
        assert len(lens) > 3
        assert max(lens) <= max(spec.prompt_lens)
        assert min(lens) >= 1


def test_sessions_carry_cumulative_context():
    reqs = SESSIONS.build(with_tokens=False)
    by_session: dict[int, list] = {}
    for r in reqs:
        by_session.setdefault(r.session, []).append(r)
    assert len(by_session) == 12               # 48 requests / 4 turns
    for turns in by_session.values():
        turns.sort(key=lambda r: r.turn)
        ctx = 0
        for r in turns:
            assert r.prefix_len == ctx         # warm cache could skip this
            assert r.prompt_len > ctx          # context re-sent + new tokens
            assert r.prefix_id == r.session
            ctx = r.prompt_len + r.gen_len
        # Turn arrivals are ordered by think time.
        arr = [r.arrival for r in turns]
        assert arr == sorted(arr)


def test_tenant_classes_drive_priority_and_slo_sampling():
    reqs = SESSIONS.build(with_tokens=False)
    by_prio: dict[int, list] = {}
    for r in reqs:
        by_prio.setdefault(r.priority, []).append(r)
    assert set(by_prio) == {0, 1, 2}
    # Premium always carries a deadline; batch never does.
    assert all(r.slo_cycles is not None for r in by_prio[0])
    assert all(r.slo_cycles is None for r in by_prio[2])
    assert TENANT_CLASSES["premium"].priority == 0
    assert workload_for(SESSIONS).kind == "mmpp"


def test_unknown_family_knobs_are_rejected():
    with pytest.raises(ValueError):
        WorkloadSpec(arrival="pareto")
    with pytest.raises(ValueError):
        WorkloadSpec(length_dist="cauchy")
    with pytest.raises(ValueError):
        WorkloadSpec(tenant_classes=("gold",))
    with pytest.raises(ValueError):
        WorkloadSpec(turns=0)


# --------------------------------------------------------------------------- #
# PrefixStore
# --------------------------------------------------------------------------- #
def test_prefix_store_lru_capacity_and_counters():
    store = PrefixStore(capacity_tokens=1000)
    assert store.insert(1, 400) == []
    assert store.insert(2, 400) == []
    assert store.hit(1, 400) == 400            # touches 1: LRU order 2, 1
    assert store.insert(3, 400) == [2]         # evicts the cold prefix
    assert store.resident(2) == 0
    assert store.hit(2, 400) == 0              # miss, counted
    assert store.hit(1, 600) == 400            # partial hit: min(resident, want)
    assert store.insert(4, 5000) == []         # oversized: simply not retained
    assert store.resident(4) == 0
    assert store.resident(1) == 400 and store.resident(3) == 400
    assert store.hits == 2 and store.misses == 1
    assert store.hit_tokens == 800 and store.evictions == 1


def test_prefix_store_checkpoint_backed_kv(tmp_path):
    store = PrefixStore(capacity_tokens=10_000, ckpt_dir=str(tmp_path))
    kv = {"k": np.arange(12, dtype=np.float32).reshape(3, 4)}
    store.insert(7, 64)
    store.attach_kv(7, kv)
    back = store.fetch_kv(7, {"k": np.zeros((3, 4), np.float32)})
    assert np.array_equal(back["k"], kv["k"])
    store.drop(7)
    assert store.resident(7) == 0


# --------------------------------------------------------------------------- #
# Affinity, priority, preemption, shedding
# --------------------------------------------------------------------------- #
def test_affinity_is_inert_on_sessionless_traces():
    """PR 9 identity: with no sessions there are no prefix ids, so turning
    the whole §13 layer on must not move a single cycle."""
    base = serve_fleet(SINGLE_TURN, config=FleetConfig(fleet=(16, 8)))
    on = serve_fleet(SINGLE_TURN, config=FleetConfig(fleet=(16, 8),
                                                     affinity=True))
    assert _served_key(base) == _served_key(on)
    assert on["metrics"].summary()["prefix"]["hits"] == 0


def test_affinity_dominates_on_session_traces():
    """Warm prefix hits skip re-prefilled context: strictly more goodput,
    no worse p99 — on both the fleet and the single-fabric paths."""
    off = serve_fleet(SESSIONS, config=FleetConfig(fleet=(16, 8)))
    on = serve_fleet(SESSIONS, config=FleetConfig(fleet=(16, 8),
                                                  affinity=True))
    s_on, s_off = on["metrics"].summary(), off["metrics"].summary()
    assert s_on["prefix"]["hits"] > 0
    assert s_on["goodput_rps"] > s_off["goodput_rps"]
    assert s_on["latency_us"]["p99"] <= s_off["latency_us"]["p99"]

    one_off = serve_workload(SESSIONS, config=ServeConfig(execute=False))
    one_on = serve_workload(SESSIONS, config=ServeConfig(execute=False,
                                                         affinity=True))
    m_on, m_off = one_on["metrics"], one_off["metrics"]
    assert m_on.prefix_hits > 0 and m_on.prefix_hit_tokens > 0
    assert m_on.summary()["goodput_rps"] >= m_off.summary()["goodput_rps"]


def test_warm_hit_shrinks_admission_and_prefill_n():
    """A turn whose deadline is infeasible for the full cumulative context
    becomes admissible once the resident prefix is skipped."""
    cal = OnlineCalibrator()
    sched = OffloadAwareScheduler(cal, available_m=(1, 2, 4, 8, 16, 32))
    model = cal.model
    # Deadline feasible for N=1024 at M=32 but not for N=4096.
    t_max = float(model.predict(32, 1024)) * 1.1
    req = Request(rid=0, arrival=0.0, prompt_len=4096, gen_len=1,
                  slo_cycles=t_max, prefix_id=5, prefix_len=3072)
    assert not sched.admit(req).admitted
    store = PrefixStore(capacity_tokens=100_000)
    store.insert(5, 3072)
    fabric = SimulatedFabric(jitter_pct=0.0)
    batcher = ContinuousBatcher(sched, cal, fabric=fabric, max_batch=4,
                                prefix_store=store)
    out = batcher.run([Request(rid=1, arrival=0.0, prompt_len=4096,
                               gen_len=1, slo_cycles=t_max, prefix_id=5,
                               prefix_len=3072)])
    r = out["requests"][0]
    assert r.t_done is not None and r.prefix_hit == 3072
    prefills = [p for p in out["plans"] if p.kind == "prefill"]
    assert prefills[0].n_elems == 4096 - 3072


def test_priority_drains_premium_first_and_preempts():
    cal = OnlineCalibrator()
    sched = OffloadAwareScheduler(cal, available_m=(1, 2, 4, 8, 16, 32))
    fabric = SimulatedFabric(jitter_pct=0.0)
    batcher = ContinuousBatcher(sched, cal, fabric=fabric, max_batch=1,
                                priority=True, preempt=True)
    # A long batch-class request occupies the only slot; a premium request
    # arrives mid-decode and must evict it.
    batch_req = Request(rid=0, arrival=0.0, prompt_len=1024, gen_len=512,
                        priority=2)
    prem = Request(rid=1, arrival=1_000.0, prompt_len=256, gen_len=4,
                   priority=0)
    out = batcher.run([batch_req, prem])
    assert batcher.metrics.preempted == 1
    assert batch_req.preemptions == 1
    assert prem.t_done is not None and batch_req.t_done is not None
    assert prem.t_done < batch_req.t_done     # premium overtook the victim
    # The victim resumed from its emitted tokens as a restore-priced job,
    # not a from-scratch regeneration.
    assert any(p.kind == "restore" for p in out["plans"])
    assert batcher.metrics.recovered == 1


def test_shed_depth_rejects_over_backlog_classes():
    sched = OffloadAwareScheduler(OnlineCalibrator(),
                                  available_m=(1, 2, 4, 8, 16, 32),
                                  shed_depth={2: 2})
    batch_req = Request(rid=0, arrival=0.0, prompt_len=256, gen_len=4,
                        priority=2)
    prem = Request(rid=1, arrival=0.0, prompt_len=256, gen_len=4,
                   priority=0)
    assert sched.admit(batch_req, backlog=2).admitted      # at the cap
    d = sched.admit(batch_req, backlog=3)                  # beyond it
    assert not d.admitted and "shed" in d.reason
    assert sched.admit(prem, backlog=50).admitted          # premium uncapped


def test_bound_handoff_prices_a_memcpy_pull():
    """Fleet mode: the router binds hit/handoff (prefix_resolved=True) and
    the lane's batcher honors the binding — the pulled KV is priced as a
    restore-kind memcpy job before the (shrunken) prefill."""
    cal = OnlineCalibrator()
    sched = OffloadAwareScheduler(cal, available_m=(1, 2, 4, 8, 16, 32))
    batcher = ContinuousBatcher(sched, cal,
                                fabric=SimulatedFabric(jitter_pct=0.0),
                                max_batch=4)
    req = Request(rid=0, arrival=0.0, prompt_len=4096, gen_len=1,
                  prefix_id=9, prefix_len=3072, prefix_hit=3072,
                  prefix_handoff=True, prefix_resolved=True)
    out = batcher.run([req])
    assert req.t_done is not None
    assert batcher.metrics.restore_jobs == 1      # the cross-lane KV pull
    assert batcher.metrics.prefix_handoffs == 1
    prefills = [p for p in out["plans"] if p.kind == "prefill"]
    assert prefills[0].n_elems == 4096 - 3072     # pulled tokens skipped


# --------------------------------------------------------------------------- #
# Config API + deprecation shims
# --------------------------------------------------------------------------- #
def test_serve_config_kwarg_shim_is_byte_identical_and_warns():
    spec = WorkloadSpec(num_requests=24, seed=3)
    new = serve_workload(spec, config=ServeConfig(execute=False,
                                                  pipeline=True))
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        old = serve_workload(spec, execute=False, pipeline=True)
    assert _served_key(new) == _served_key(old)
    assert old["config"] == ServeConfig(execute=False, pipeline=True)
    # Kwargs override an explicit config through the same replace path.
    with pytest.warns(DeprecationWarning):
        mixed = serve_workload(spec, config=ServeConfig(pipeline=True),
                               execute=False)
    assert _served_key(mixed) == _served_key(new)


def test_fleet_config_kwarg_shim_is_byte_identical_and_warns():
    spec = WorkloadSpec(num_requests=24, seed=3)
    new = serve_fleet(spec, config=FleetConfig(fleet=(16, 8)))
    with pytest.warns(DeprecationWarning, match="FleetConfig"):
        old = serve_fleet(spec, fleet=(16, 8))
    assert _served_key(new) == _served_key(old)


def test_unknown_kwargs_still_raise_type_error():
    with pytest.raises(TypeError):
        with pytest.warns(DeprecationWarning):
            serve_workload(WorkloadSpec(num_requests=4), exectue=False)


def test_synthetic_workload_is_a_deprecated_alias():
    spec = WorkloadSpec(num_requests=16, seed=1)
    with pytest.warns(DeprecationWarning, match="WorkloadSpec.build"):
        old = synthetic_workload(spec, with_tokens=False)
    assert _trace_key(old) == _trace_key(spec.build(with_tokens=False))


def test_configs_are_frozen():
    with pytest.raises(Exception):
        ServeConfig().execute = False
    with pytest.raises(Exception):
        FleetConfig().fleet = (8,)
