"""Dry-run machinery tests: HLO collective parsing (trip counts, operand
byte rules) and an end-to-end miniature dry-run on 8 virtual devices."""

import textwrap

import pytest

from repro.launch.dryrun import _shape_bytes, parse_collectives


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[16]") == 32
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("s32[2,2,2]") == 32


FIXTURE = textwrap.dedent("""
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(f32[] %a, f32[] %b)
    }

    %cond (s: (s32[], f32[64])) -> pred[] {
      %c = s32[] constant(7)
      %i = s32[] get-tuple-element((s32[], f32[64]) %s), index=0
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body (s: (s32[], f32[64])) -> (s32[], f32[64]) {
      %x = f32[64]{0} get-tuple-element(%s), index=1
      %ar = f32[64]{0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
      ROOT %t = (s32[], f32[64]) tuple(%i2, %ar)
    }

    ENTRY %main (p: f32[64]) -> f32[64] {
      %ag = f32[64]{0} all-gather(f32[8]{0} %p), channel_id=2, replica_groups=[1,8]<=[8], dimensions={0}
      %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
      %rs = f32[8]{0} reduce-scatter(f32[64]{0} %q), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}, to_apply=%add
      ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
    }
""")


def test_parse_collectives_trip_counts_and_bytes():
    out = parse_collectives(FIXTURE)
    by_kind = {o["kind"]: o for o in out["ops"]}
    # all-reduce inside the while body: multiplied by the trip count (7).
    ar = by_kind["all-reduce"]
    assert ar["multiplier"] == 7
    assert ar["operand_bytes"] == 64 * 4
    assert ar["group_size"] == 2
    # all-gather at top level: operand = result / group.
    ag = by_kind["all-gather"]
    assert ag["multiplier"] == 1
    assert ag["operand_bytes"] == 64 * 4 // 8
    # reduce-scatter: operand = result * group.
    rs = by_kind["reduce-scatter"]
    assert rs["operand_bytes"] == 8 * 4 * 8
    # totals multiply by trips.
    assert out["per_device_bytes_by_kind"]["all-reduce"] == 7 * 256
    # ring-effective: AR = 2x operand x (g-1)/g.
    assert ar["effective_bytes"] == int(2 * 256 * 1 / 2)


@pytest.mark.slow
def test_miniature_dryrun_cell_end_to_end():
    """Run the real dry-run path (steps + shardings + compile + analysis)
    on a 4x2 mesh with a reduced config, in a subprocess."""
    from conftest import run_py
    r = run_py("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.shapes import config_for_shape
from repro.launch.mesh import make_mesh
from repro.launch.steps import bundle_for
from repro.launch.dryrun import parse_collectives
from repro.models import scaled_down
import dataclasses

mesh = make_mesh((4, 2), ("data", "model"))
cfg = scaled_down(get_config("qwen3-moe-30b-a3b"))
cfg = dataclasses.replace(cfg, num_heads=4, num_kv_heads=2, moe_groups=8)
specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
bundle = bundle_for(cfg, mesh, "train_4k", specs)
with mesh:
    compiled = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings,
                       donate_argnums=bundle.donate_argnums
                       ).lower(*bundle.abstract_args).compile()
ma = compiled.memory_analysis()
from repro.launch.dryrun import peak_memory_bytes
assert peak_memory_bytes(ma) > 0
colls = parse_collectives(compiled.as_text())
kinds = set(colls["per_device_bytes_by_kind"])
assert colls["per_device_bytes_total"] > 0
print("OK", sorted(k for k, v in colls["per_device_bytes_by_kind"].items()
                   if v > 0))
""", devices=8)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_decode_bundle_compiles_with_kv_quant():
    from conftest import run_py
    r = run_py("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_decode_step
from repro.models import init_cache, scaled_down

mesh = make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(scaled_down(get_config("granite-3-8b")),
                          kv_quant=True, num_heads=4, num_kv_heads=2)
caches = jax.eval_shape(lambda: init_cache(cfg, 4, max_len=64))
specs = {"tokens": jax.ShapeDtypeStruct((4, 1), jnp.int32),
         "caches": caches,
         "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}
bundle = make_decode_step(cfg, mesh, specs)
with mesh:
    compiled = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings,
                       donate_argnums=bundle.donate_argnums
                       ).lower(*bundle.abstract_args).compile()
from repro.launch.dryrun import peak_memory_bytes
print("OK", peak_memory_bytes(compiled.memory_analysis()) > 0)
""", devices=8)
    assert r.returncode == 0 and "OK True" in r.stdout, r.stderr[-3000:]
