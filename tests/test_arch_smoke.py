"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-like grad step + one decode step on CPU; asserts output
shapes and absence of NaNs. Full-size configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""


import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, all_configs
from repro.configs.shapes import (SHAPE_NAMES, cell_table, input_specs,
                                  shape_applicable)
from repro.models import (cross_entropy, decode_step, forward, init_cache,
                          init_params, scaled_down)


@pytest.fixture(scope="module")
def configs():
    return all_configs()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch, configs):
    cfg = configs[arch]
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_extras(configs):
    for a in ("qwen3-moe-235b-a22b", "qwen3-moe-30b-a3b"):
        assert configs[a].num_experts == 128
        assert configs[a].num_experts_per_tok == 8
    assert configs["zamba2-1.2b"].ssm_state == 64
    assert configs["mamba2-370m"].ssm_state == 128


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_reduced_config(arch, configs):
    cfg = scaled_down(configs[arch])
    params = init_params(jax.random.key(0), cfg)
    b, s = 2, 16
    use_embeds = cfg.frontend == "vision_patches"
    if use_embeds:
        batch = {"embeds": jax.random.normal(
            jax.random.key(1), (b, s, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(
            jax.random.key(1), (b, s), 0, cfg.vocab_size)}
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)

    # Forward: shape + finiteness.
    logits = jax.jit(lambda p: forward(p, cfg, **batch))(params)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"NaN in {arch} forward"

    # One train-style step: grads exist and are finite.
    def loss_fn(p):
        return cross_entropy(forward(p, cfg, **batch), labels)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), \
        f"NaN grad in {arch}"

    # One decode step.
    cache = init_cache(cfg, b, max_len=32)
    tok = jnp.zeros((b, 1), jnp.int32)
    lg, cache2 = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.int32(0)))(
            params, tok, cache)
    assert lg.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), f"NaN in {arch} decode"
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_are_abstract(arch, configs):
    cfg = configs[arch]
    for shape in SHAPE_NAMES:
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            assert "full-attention" in why
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_cell_matrix_is_40_cells(configs):
    rows = cell_table(configs)
    assert len(rows) == 40
    skipped = [(a, s) for a, s, ok, _ in rows if not ok]
    # Exactly the 7 pure full-attention archs skip long_500k.
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
    runnable = {a for a, s, ok, _ in rows if s == "long_500k" and ok}
    assert runnable == {"mamba2-370m", "zamba2-1.2b", "gemma3-12b"}


def test_long_500k_specs_for_subquadratic(configs):
    for arch in ("mamba2-370m", "zamba2-1.2b", "gemma3-12b"):
        specs = input_specs(configs[arch], "long_500k")
        assert specs["tokens"].shape == (1, 1)
        # Ring-buffered local caches stay at the window size.
        if arch == "gemma3-12b":
            local_k = specs["caches"]["groups"][0]["k"]
            assert local_k.shape[2] == 1024  # (groups, B, W, K, D) -> W
            glob_k = specs["caches"]["groups"][5]["k"]
            assert glob_k.shape[2] == 524_288


def test_param_counts_match_billing_names(configs):
    """Sanity: analytic param counts land near the names' billions."""
    expect = {
        "starcoder2-15b": (14e9, 17e9),
        "granite-3-8b": (7e9, 9.5e9),
        "gemma3-12b": (10e9, 14e9),
        "chatglm3-6b": (5.5e9, 7e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "qwen3-moe-30b-a3b": (27e9, 33e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "musicgen-large": (1.5e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs[arch].param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params(configs):
    cfg = configs["qwen3-moe-235b-a22b"]
    active = cfg.active_param_count()
    assert 18e9 <= active <= 26e9, active / 1e9  # "a22b"
